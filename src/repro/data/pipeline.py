"""Sharded data pipeline.

Two sources behind one interface:

* ``SyntheticBigramSource`` — tokens drawn from a fixed random bigram
  chain.  The distribution has ~``entropy_bits`` of conditional entropy,
  so a trained LM's loss has a KNOWN floor: examples/tests can assert
  convergence toward it (cross-entropy -> H(next|prev)) rather than just
  "loss went down".
* ``FileTokenSource`` — memory-mapped flat token file (uint16/uint32),
  the production path.

Sharding: each data-parallel rank reads its own disjoint slice — the
pipeline takes (shard_id, num_shards) exactly like a tf.data shard, and
batches are emitted host-side as numpy then device_put with the batch
PartitionSpec by the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticBigramSource:
    """next ~ Cat(T[prev]) with a sparse random transition table."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 4):
        self.vocab_size = vocab_size
        self.branching = branching
        rng = np.random.default_rng(seed)
        # each token can be followed by `branching` successors, skewed probs
        self.succ = rng.integers(0, vocab_size, (vocab_size, branching))
        raw = rng.exponential(1.0, (vocab_size, branching))
        self.probs = raw / raw.sum(-1, keepdims=True)

    @property
    def entropy_bits(self) -> float:
        p = self.probs
        return float(-(p * np.log2(p)).sum(-1).mean())

    @property
    def entropy_nats(self) -> float:
        p = self.probs
        return float(-(p * np.log(p)).sum(-1).mean())

    def sample(self, rng: np.random.Generator, batch: int,
               seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, batch)
        for t in range(seq):
            prev = toks[:, t]
            choice = np.array(
                [rng.choice(self.branching, p=self.probs[p]) for p in prev]
            ) if batch <= 64 else self._vectorized_choice(rng, prev)
            toks[:, t + 1] = self.succ[prev, choice]
        return toks

    def _vectorized_choice(self, rng, prev):
        u = rng.random(prev.shape[0])
        cdf = np.cumsum(self.probs[prev], -1)
        return (u[:, None] < cdf).argmax(-1)


class FileTokenSource:
    """Flat binary token file; slices are drawn at random offsets."""

    def __init__(self, path: str, vocab_size: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size

    def sample(self, rng: np.random.Generator, batch: int,
               seq: int) -> np.ndarray:
        hi = len(self.tokens) - seq - 1
        starts = rng.integers(0, hi, batch)
        return np.stack([self.tokens[s:s + seq + 1] for s in starts]
                        ).astype(np.int32)


@dataclasses.dataclass
class DataPipeline:
    source: object
    batch: int          # per-shard batch
    seq: int
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 0

    def __post_init__(self):
        # disjoint per-shard streams: distinct substream per shard
        self.rng = np.random.default_rng(
            np.random.SeedSequence(self.seed).spawn(self.num_shards)
            [self.shard_id])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            toks = self.source.sample(self.rng, self.batch, self.seq)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, n: int):
        it = iter(self)
        for _ in range(n):
            yield next(it)


def make_pipeline(vocab_size: int, batch: int, seq: int, *,
                  path: Optional[str] = None, shard_id: int = 0,
                  num_shards: int = 1, seed: int = 0) -> DataPipeline:
    src = (FileTokenSource(path, vocab_size) if path
           else SyntheticBigramSource(vocab_size, seed))
    return DataPipeline(src, batch, seq, shard_id, num_shards, seed)
