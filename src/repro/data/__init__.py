from repro.data.pipeline import (SyntheticBigramSource, FileTokenSource,
                                 DataPipeline, make_pipeline)
