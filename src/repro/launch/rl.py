"""RL fleet launcher: the Ape-X/IMPALA actor–learner loop as a CLI.

Runs `repro.rl.fleet.run_fleet` on the cluster control plane: N actors
roll out with periodically-pulled (stale) parameters, push prioritized
trajectories to a sharded replay service, and one learner samples
V-trace-corrected batches and publishes new parameter versions —
survey refs 98 (GORILA), 101 (IMPALA), 104 (Ape-X).

The shared cluster flags (`repro.launch.cli`) pick the control plane:
``--transport sim`` (default) replays an optional ``--failure-trace``
on the deterministic simulated clock; ``--transport proc`` runs every
actor, replay shard, and the learner as a real child process — the
learner trajectory is bit-identical either way.

Usage:
  PYTHONPATH=src python -m repro.launch.rl --actors 4 --replay-shards 2 \
      --steps 40
  PYTHONPATH=src python -m repro.launch.rl --transport proc \
      --failure-trace trace.json --trace-out rl_trace.json
"""
from __future__ import annotations

import argparse

from repro.launch import cli


def rl(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--actors", type=int, default=4)
    ap.add_argument("--replay-shards", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40,
                    help="fleet rounds (1.0 simulated time unit each)")
    ap.add_argument("--rollout-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=16,
                    help="learner sample size per step")
    ap.add_argument("--pull-every", type=int, default=4,
                    help="actor pulls fresh params every N rollouts "
                         "(staleness bound)")
    ap.add_argument("--capacity", type=int, default=1024,
                    help="replay ring capacity per shard")
    ap.add_argument("--alpha", type=float, default=0.6,
                    help="priority exponent (Ape-X)")
    ap.add_argument("--beta", type=float, default=0.4,
                    help="importance-weight exponent")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--gamma", type=float, default=0.97)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    cli.add_cluster_args(ap, context="the actor–learner fleet")
    cli.add_trace_args(ap)
    args = ap.parse_args(argv)
    return cli.run_traced(args, lambda: _rl(args))


def _rl(args) -> dict:
    from repro.rl.fleet import run_fleet

    trace = cli.load_failure_trace(args)
    res = run_fleet(
        actors=args.actors, replay_shards=args.replay_shards,
        steps=args.steps, rollout_len=args.rollout_len, batch=args.batch,
        pull_every=args.pull_every, capacity=args.capacity,
        alpha=args.alpha, beta=args.beta, lr=args.lr, gamma=args.gamma,
        hidden=args.hidden, seed=args.seed,
        transport=cli.make_transport(args, trace))

    print(f"fleet: actors={args.actors} shards={args.replay_shards} "
          f"transport={args.transport} trace="
          f"{args.failure_trace or '<failure-free>'}")
    print(f"  env_steps={res.env_steps} over {res.sim_time:.0f} sim-time "
          f"-> goodput={res.goodput:.2f} steps/time")
    print(f"  learner: {res.learner_steps} steps, published version "
          f"{res.final_version}, final loss "
          f"{res.losses[-1]:.4f}" if res.losses else
          "  learner: 0 steps (replay never filled — raise --steps "
          "or lower --batch)")
    print(f"  staleness: mean={res.staleness_mean:.2f} "
          f"max={res.staleness_max} (pull_every={args.pull_every})")
    print(f"  survivors: actors={list(res.final_actors)} "
          f"shards={list(res.final_shards)}  "
          f"greedy return={res.final_return:.3f}")
    return {"goodput": res.goodput, "losses": res.losses,
            "env_steps": res.env_steps, "learner_steps": res.learner_steps,
            "staleness_mean": res.staleness_mean,
            "staleness_max": res.staleness_max,
            "final_return": res.final_return,
            "transitions": res.transitions}


if __name__ == "__main__":
    from repro.obs import log as _log
    _log.configure()  # CLI runs show [info] progress; library use stays quiet
    rl()
