"""Training launcher.

Drives any registered architecture (``--arch``, ``--smoke`` for the
reduced variant) on the active device set: 1 CPU device for local runs,
a host mesh for multi-device CPU integration (set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE launching),
or the production TPU mesh.

The survey's parallelism taxonomy is selected by ``--env``:
  dp       data parallelism only
  dp_tp    hybrid data x tensor (production default)
  tp       model/tensor parallelism only
  fsdp     dp_tp + ZeRO param/optimizer sharding

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 100 --batch 8 --seq 256 --data 1 --model 1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.core import sharding as SH
from repro.data import make_pipeline
from repro.launch import cli
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import batch_pspecs, batch_abstract, make_train_step
from repro.models import model as MD
from repro.obs import recorder as obs
from repro.optim.optimizers import get_optimizer, warmup_cosine

ENVS = {
    "dp": SH.DP_ENV,
    "dp_tp": SH.DP_TP_ENV,
    "tp": SH.TP_ENV,
    "fsdp": SH.TRAIN_ENV,
}


def train(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--env", default="dp_tp", choices=list(ENVS))
    ap.add_argument("--data", type=int, default=1, help="data mesh dim")
    ap.add_argument("--model", type=int, default=1, help="model mesh dim")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true",
                    help="natural compression on gradients (survey ref 75)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic training: survive worker death/join/"
                         "slowdown from a failure trace (repro.elastic)")
    cli.add_cluster_args(ap, context="--elastic", workers=4,
                         workers_help="logical data-parallel workers "
                                      "for --elastic")
    cli.add_trace_args(ap)
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "local_sgd", "easgd", "async_ps",
                             "ssp"],
                    help="--elastic training mode (repro.elastic.modes): "
                         "sync all-reduce with checkpoint/rewind recovery "
                         "(default); local_sgd/easgd per-worker replicas "
                         "with survivor continuation; async_ps/ssp "
                         "parameter-server push/pull on the cluster "
                         "transport")
    ap.add_argument("--staleness", type=int, default=2,
                    help="--mode=ssp staleness bound s: a worker may run "
                         "at most s clocks ahead of the slowest")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoint retention for --elastic")
    ap.add_argument("--async-ckpt", dest="async_ckpt", action="store_true",
                    default=None,
                    help="non-blocking checkpoint saves on a background "
                         "writer (repro.checkpoint.AsyncCheckpointer); "
                         "default: on for --elastic, off otherwise")
    ap.add_argument("--no-async-ckpt", dest="async_ckpt",
                    action="store_false")
    args = ap.parse_args(argv)
    if args.elastic and args.mode == "sync" and not args.ckpt_dir:
        ap.error("--elastic --mode=sync requires --ckpt-dir (sync "
                 "recovery restores from the last checkpoint); other "
                 "modes checkpoint only when --ckpt-dir is given")
    if args.async_ckpt is None:
        # elastic checkpoints every ~10-20 steps: a blocking save there
        # steals a full step from every worker, so async is the default
        args.async_ckpt = args.elastic

    return cli.run_traced(args, lambda: _train(args))


def _train(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    # keep params fp32 on CPU for small-scale training stability
    if jax.default_backend() == "cpu":
        cfg = cfg.with_(param_dtype="float32", compute_dtype="float32")

    mesh = make_host_mesh(args.data, args.model)
    opt = get_optimizer(args.optimizer,
                        warmup_cosine(args.lr, 20, args.steps))

    with SH.use_mesh(mesh), SH.axis_env(ENVS[args.env]):
        pspecs = MD.model_pspecs(cfg)
        params = jax.jit(
            lambda k: MD.init_model(cfg, k),
            out_shardings=jax.tree_util.tree_map(
                lambda p: NamedSharding(mesh, p), pspecs,
                is_leaf=lambda x: isinstance(x, P)),
        )(jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(opt.init)(params)

        step0 = 0
        if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            abs_tree = {"params": jax.eval_shape(lambda: params),
                        "opt": jax.eval_shape(lambda: opt_state)}
            tree, meta = restore_checkpoint(args.ckpt_dir, abs_tree)
            params, opt_state = tree["params"], tree["opt"]
            step0 = meta.get("step", 0)
            print(f"resumed from step {step0}")

        batch_abs = batch_abstract(cfg, args.batch, args.seq)
        bspecs = batch_pspecs(cfg, batch_abs)
        bshard = jax.tree_util.tree_map(
            lambda p: NamedSharding(mesh, p), bspecs,
            is_leaf=lambda x: isinstance(x, P))
        step_fn = jax.jit(
            make_train_step(cfg, opt, compress_grads=args.compress_grads),
            donate_argnums=(0, 1))

        pipe = make_pipeline(cfg.vocab_size, args.batch, args.seq,
                             seed=args.seed)
        entropy_floor = pipe.source.entropy_nats

        if args.elastic:
            from repro.elastic import elastic_lm_loop
            out = elastic_lm_loop(
                args=args, cfg=cfg, step_fn=step_fn, params=params,
                opt_state=opt_state, bshard=bshard, batch_abs=batch_abs,
                pipe_factory=lambda shard, num: make_pipeline(
                    cfg.vocab_size, args.batch, args.seq,
                    shard_id=shard, num_shards=num, seed=args.seed),
                step0=step0, opt=opt,
                loss_fn=lambda p, b: MD.lm_loss(p, cfg, b))
            return {"losses": out["losses"],
                    "entropy_floor": entropy_floor,
                    "params": out["params"],
                    "recoveries": out["recoveries"],
                    "final_alive": out["final_alive"],
                    "transitions": out["transitions"]}

        saver = (AsyncCheckpointer(args.ckpt_dir)
                 if args.async_ckpt and args.ckpt_dir else None)

        def _save(at_step):
            tree = {"params": params, "opt": opt_state}
            meta = {"step": at_step, "arch": args.arch}
            if saver is not None:
                saver.save(at_step, tree, meta)
            else:
                save_checkpoint(args.ckpt_dir, at_step, tree, meta)

        losses = []
        t0 = time.time()
        try:
            for i, batch in enumerate(pipe.batches(args.steps)):
                step = step0 + i
                dev_batch = {k: jax.device_put(v, bshard[k])
                             for k, v in batch.items()}
                if cfg.arch_type in ("vlm", "audio"):
                    ee = batch_abs["extra_embeds"]
                    dev_batch["extra_embeds"] = jnp.zeros(ee.shape, ee.dtype)
                extra = ((jax.random.PRNGKey(args.seed + 1 + step),)
                         if args.compress_grads else ())
                with obs.get().span("train.step", cat="train", step=step):
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         dev_batch, *extra)
                    loss = float(metrics["loss"])
                losses.append(loss)
                if step % args.log_every == 0:
                    dt = time.time() - t0
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"(floor~{entropy_floor:.3f}) "
                          f"gnorm {float(metrics['gnorm']):.3f} "
                          f"{dt / max(i, 1):.2f}s/step", flush=True)
                if (args.ckpt_dir and args.ckpt_every
                        and (step + 1) % args.ckpt_every == 0):
                    _save(step + 1)

            if args.ckpt_dir:
                _save(step0 + args.steps)
            if saver is not None:
                saver.wait()  # barrier: the final save is durable on return
        finally:
            if saver is not None:
                saver.close(wait=False)  # never leak the writer thread

    return {"losses": losses, "entropy_floor": entropy_floor,
            "params": params}


if __name__ == "__main__":
    from repro.obs import log as _log
    _log.configure()  # CLI runs show [info] progress; library use stays quiet
    train()
