"""Production meshes (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 256 chips as (16, 16) ("data", "model"); multi-pod:
2 pods = 512 chips as (2, 16, 16) ("pod", "data", "model") — the "pod"
axis crosses DCN, so the launcher maps only low-volume collectives
(data-parallel gradient reduction or pipeline stages) onto it.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small CPU mesh for integration tests (requires
    --xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))
