"""Serving launcher: static batched serving or continuous batching.

Static (default): a batch of requests is prefilled once (builds the cache),
then decoded token-by-token in lockstep — the whole batch advances behind
one scalar position and retires when its longest request finishes.

Continuous (--continuous): the `repro.serving.ServeEngine` slot pool —
per-request position vectors, active-mask gated cache updates, and FIFO
admission that backfills a slot the moment its request retires, so a
mixed-length request stream sustains near-full batch occupancy.

Elastic fleet (--replicas N): N continuous-batching replicas behind the
straggler-aware router, driven by the same trace-driven membership
machine as elastic training — replica death drains + re-admits in-flight
requests across survivors (`--failure-trace` replays crash / hang /
join / slow events; without one the fleet runs failure-free).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --continuous --requests 16 --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --replicas 3 --requests 24 --batch 2 --failure-trace trace.json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sharding as SH
from repro.launch import cli
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import sharded_argmax
from repro.models import model as MD
from repro.obs import recorder as obs


def _make_extra(cfg, B):
    if cfg.arch_type == "vlm":
        return jnp.zeros((B, cfg.num_patches, MD.VISION_EMBED_DIM),
                         jnp.dtype(cfg.compute_dtype))
    if cfg.arch_type == "audio":
        return jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                         jnp.dtype(cfg.compute_dtype))
    return None


def make_static_fns(cfg, cache_len, extra=None):
    """Jitted (prefill, decode) pair for the static serve path — also the
    baseline benchmarks/bench_serving.py measures against."""

    @jax.jit
    def prefill(params, tokens):
        logits, _, cache = MD.forward(params, cfg, tokens,
                                      extra_embeds=extra,
                                      return_cache=True,
                                      cache_len=cache_len)
        # sharded_argmax keeps the model-sharded vocab dim sharded: a plain
        # jnp.argmax re-all-gathers full logits every token (steps.py)
        nxt = sharded_argmax(logits[:, -1])[:, None]
        return nxt, cache

    @jax.jit
    def decode(params, tok, pos, cache):
        logits, cache = MD.decode_step(params, cfg, tok, pos, cache)
        nxt = sharded_argmax(logits[:, -1])[:, None]
        return nxt, cache

    return prefill, decode


def _serve_static(params, cfg, args):
    B, S, G = args.batch, args.prompt_len, args.gen
    # the VLM prepends patch embeddings: the cache must hold them too
    cache_len = S + G + (cfg.num_patches if cfg.arch_type == "vlm" else 0)
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (B, S), 0, cfg.vocab_size)
    prefill, decode = make_static_fns(cfg, cache_len, _make_extra(cfg, B))

    t0 = time.time()
    tok, cache = prefill(params, prompts)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        # VLM caches include the patch prefix before the prompt tokens
        pos = S + i + (cfg.num_patches if cfg.arch_type == "vlm" else 0)
        tok, cache = decode(params, tok, jnp.int32(pos), cache)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    tput = B * (G - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} B={B} prompt={S} gen={G}")
    print(f"prefill: {t_prefill:.3f}s   decode: {t_decode:.3f}s "
          f"({tput:.1f} tok/s incl. compile)")
    print("sample generation (first request):", gen[0, :16].tolist())
    return {"generated": gen, "t_prefill": t_prefill, "t_decode": t_decode}


def _serve_continuous(params, cfg, args):
    from repro.serving import ServeEngine
    from repro.serving.speculative import (LookupDraft, ModelDraft,
                                           SpecDecodeEngine)

    # drawn lengths never exceed the CLI bounds: cache_len = S + G must
    # hold the longest prompt plus the largest generation budget
    S, G = args.prompt_len, args.gen
    reqs = _make_stream(cfg, args)
    n_prefix = cfg.num_patches if cfg.arch_type == "vlm" else 0
    cache_len = S + G + n_prefix
    paged = dict(page_size=args.page_size,
                 num_pages=args.num_pages) if args.paged else {}
    if args.speculative:
        if args.draft_arch:
            dcfg = get_config(args.draft_arch, smoke=args.smoke)
            if jax.default_backend() == "cpu":
                dcfg = dcfg.with_(param_dtype="float32",
                                  compute_dtype="float32")
            dparams = jax.jit(lambda k: MD.init_model(dcfg, k))(
                jax.random.PRNGKey(args.seed + 7))
            draft = ModelDraft(dparams, dcfg)
        else:
            draft = LookupDraft()
        engine = SpecDecodeEngine(params, cfg, num_slots=args.batch,
                                  cache_len=cache_len + args.spec_k,
                                  draft=draft, spec_k=args.spec_k, **paged)
    else:
        engine = ServeEngine(params, cfg, num_slots=args.batch,
                             cache_len=cache_len, **paged)

    t0 = time.time()
    finished = engine.run(reqs)
    dt = time.time() - t0
    st = engine.stats()
    tput = st["generated_tokens"] / max(dt, 1e-9)
    print(f"arch={cfg.name} slots={args.batch} requests={args.requests} "
          f"prompt<=~{S} gen<={G}")
    print(f"continuous: {dt:.3f}s  {st['generated_tokens']} tokens "
          f"({tput:.1f} tok/s incl. compile)  "
          f"occupancy={st['occupancy']:.2f}  "
          f"ticks={st['ticks']} (prefill {st['prefill_ticks']}, "
          f"decode {st['decode_ticks']})")
    if args.paged:
        print(f"paged: page_size={engine.page_size} "
              f"pages={engine.num_pages} "
              f"pool_occupancy={st['pool_occupancy']:.2f} "
              f"preemptions={st['preemptions']}")
    if args.speculative:
        print(f"speculative: k={args.spec_k} "
              f"draft={'model:' + args.draft_arch if args.draft_arch else 'lookup'} "
              f"rounds={st['spec_rounds']} "
              f"accept_rate={st['accept_rate']:.2f} "
              f"tokens/round={st['tokens_per_round']:.2f}")
    print("sample generation (first request):",
          finished[0].tokens[:16])
    return {"finished": finished, "stats": st, "t_total": dt}


def _make_stream(cfg, args):
    """Deterministic mixed-length request stream shared by the continuous
    and fleet paths."""
    from repro.serving import Request

    rng = np.random.RandomState(args.seed + 1)
    S, G = args.prompt_len, args.gen
    plens = sorted({min(S, max(1, S // 2)), min(S, max(1, 3 * S // 4)), S})
    gens = sorted({max(1, G // 4), max(1, G // 2), G})
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=int(rng.choice(plens))),
                    max_new_tokens=int(rng.choice(gens)))
            for i in range(args.requests)]
    if cfg.arch_type in ("vlm", "audio"):
        for r in reqs:
            r.extra_embeds = _make_extra(cfg, 1)
    return reqs


def _serve_fleet(params, cfg, args):
    from repro.serving import ServeFleet

    trace = cli.load_failure_trace(args)
    transport = (cli.make_transport(args, trace)
                 if args.transport == "proc" else None)
    n_prefix = cfg.num_patches if cfg.arch_type == "vlm" else 0
    fleet = ServeFleet(params, cfg, replicas=args.replicas,
                       num_slots=args.batch,
                       cache_len=args.prompt_len + args.gen + n_prefix,
                       trace=None if transport else trace,
                       transport=transport,
                       page_size=args.page_size if args.paged else None,
                       num_pages=args.num_pages if args.paged else None,
                       hedged_decode=args.hedged)
    reqs = _make_stream(cfg, args)
    t0 = time.time()
    try:
        finished = fleet.run(reqs)
    finally:
        fleet.close()
    dt = time.time() - t0
    st = fleet.stats()
    print(f"arch={cfg.name} replicas={args.replicas} slots={args.batch} "
          f"requests={args.requests} trace="
          f"{args.failure_trace or '<failure-free>'}")
    print(f"fleet: {dt:.3f}s wall={st['wall']} ticks  "
          f"{st['delivered_tokens']} tokens  "
          f"goodput={st['goodput']:.2f} tok/wall-tick  "
          f"drains={st['drains']} readmitted={st['readmitted']}  "
          f"survivors={st['replicas']}")
    print(f"routing: {st['routed']}")
    print("sample generation (first request):", finished[0].tokens[:16])
    return {"finished": finished, "stats": st, "t_total": dt}


def serve(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static: batch size; continuous: pool slots")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a slot pool "
                         "(repro.serving.ServeEngine)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="elastic fleet of N continuous-batching replicas "
                         "(repro.serving.ServeFleet); --batch = slots per "
                         "replica")
    ap.add_argument("--requests", type=int, default=16,
                    help="--continuous/--replicas: requests in the stream")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache pool: slots share fixed-size "
                         "pages instead of reserving max-length rows")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--paged: tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="--paged: pool pages (default: worst-case "
                         "slots x ceil(cache_len/page_size))")
    ap.add_argument("--speculative", action="store_true",
                    help="--continuous: draft-verify decoding "
                         "(repro.serving.speculative); bit-identical "
                         "output, fewer target dispatches")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="--speculative: draft tokens per round")
    ap.add_argument("--draft-arch", default=None,
                    help="--speculative: config-zoo arch drafting for "
                         "--arch (e.g. qwen3-0.6b for qwen3-1.7b); "
                         "default: model-free n-gram lookup draft")
    ap.add_argument("--hedged", action="store_true",
                    help="--replicas: hedged decode — SUSPECT replicas "
                         "keep serving while a speculative continuation "
                         "races them on a healthy replica "
                         "(first-token-wins)")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    cli.add_cluster_args(ap, context="--replicas")
    cli.add_trace_args(ap)
    args = ap.parse_args(argv)

    return cli.run_traced(args, lambda: _serve(args))


def _serve(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    if jax.default_backend() == "cpu":
        cfg = cfg.with_(param_dtype="float32", compute_dtype="float32")

    mesh = make_host_mesh(args.data, args.model)
    with SH.use_mesh(mesh), SH.axis_env(SH.DP_TP_ENV):
        params = jax.jit(lambda k: MD.init_model(cfg, k))(
            jax.random.PRNGKey(args.seed))
        if args.replicas:
            return _serve_fleet(params, cfg, args)
        if args.continuous:
            return _serve_continuous(params, cfg, args)
        return _serve_static(params, cfg, args)


if __name__ == "__main__":
    from repro.obs import log as _log
    _log.configure()  # CLI runs show [info] progress; library use stays quiet
    serve()
