"""Serving launcher: batched prefill + decode with a KV/state cache.

Implements the production serve path the decode dry-run shapes lower:
a batch of requests is prefilled once (builds the cache), then decoded
token-by-token with `serve_step` (one token against the cache).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD


def serve(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if jax.default_backend() == "cpu":
        cfg = cfg.with_(param_dtype="float32", compute_dtype="float32")
    B, S, G = args.batch, args.prompt_len, args.gen
    cache_len = S + G

    mesh = make_host_mesh(args.data, args.model)
    with SH.use_mesh(mesh), SH.axis_env(SH.DP_TP_ENV):
        params = jax.jit(lambda k: MD.init_model(cfg, k))(
            jax.random.PRNGKey(args.seed))
        prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                     (B, S), 0, cfg.vocab_size)
        extra = None
        if cfg.arch_type == "vlm":
            extra = jnp.zeros((B, cfg.num_patches, MD.VISION_EMBED_DIM),
                              jnp.dtype(cfg.compute_dtype))
        if cfg.arch_type == "audio":
            extra = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))

        @jax.jit
        def prefill(params, tokens):
            logits, _, cache = MD.forward(params, cfg, tokens,
                                          extra_embeds=extra,
                                          return_cache=True,
                                          cache_len=cache_len)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            return nxt, cache

        @jax.jit
        def decode(params, tok, pos, cache):
            logits, cache = MD.decode_step(params, cfg, tok, pos, cache)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            return nxt, cache

        t0 = time.time()
        tok, cache = prefill(params, prompts)
        tok.block_until_ready()
        t_prefill = time.time() - t0

        out = [tok]
        t0 = time.time()
        for i in range(G - 1):
            # VLM caches include the patch prefix before the prompt tokens
            pos = S + i + (cfg.num_patches if cfg.arch_type == "vlm" else 0)
            tok, cache = decode(params, tok, jnp.int32(pos), cache)
            out.append(tok)
        jax.block_until_ready(out[-1])
        t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    tput = B * (G - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} B={B} prompt={S} gen={G}")
    print(f"prefill: {t_prefill:.3f}s   decode: {t_decode:.3f}s "
          f"({tput:.1f} tok/s incl. compile)")
    print("sample generation (first request):", gen[0, :16].tolist())
    return {"generated": gen, "t_prefill": t_prefill, "t_decode": t_decode}


if __name__ == "__main__":
    serve()
