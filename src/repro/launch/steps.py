"""Step builders: train / prefill / serve steps with full sharding specs,
plus abstract input specs (ShapeDtypeStruct) for AOT lowering (the dry-run
never allocates real arrays for the production configs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import InputShape
from repro.core import sharding as SH
from repro.core.compression import natural_compress
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.optim.optimizers import clip_by_global_norm, get_optimizer, warmup_cosine


# ---------------------------------------------------------------------------
# Cache sharding specs
# ---------------------------------------------------------------------------
def _kv_cache_names(cfg: ModelConfig) -> tuple:
    """KV cache (L,B,C,Hk,dh) sharding: heads on the model axis when they
    divide it; otherwise CONTEXT-SHARD the cache length C.  A non-divisible
    head dim used to fall back to a replicated cache, which GSPMD then
    re-all-gathered every decode step (the whole 32k cache per token —
    EXPERIMENTS.md §Perf, decode iteration)."""
    shards = SH.axis_size(SH.get_axis_env().resolve("model"))
    if shards <= 1 or cfg.num_kv_heads % shards == 0:
        return ("layers", "batch", None, "model", None)
    return ("layers", "batch", "model", None, None)


def _cache_spec_names(cfg: ModelConfig) -> Dict[str, Any]:
    at = cfg.arch_type
    kv = _kv_cache_names(cfg)
    if at in ("dense", "vlm", "moe", "audio"):
        names = {"k": kv, "v": kv}
        if at == "audio":
            names["ck"] = kv
            names["cv"] = kv
        return names
    if at == "hybrid":
        return {"ssm": ("layers", "batch", "model", None, None),
                "conv": {"x": ("layers", "batch", None, "model"),
                         "B": ("layers", "batch", None, None),
                         "C": ("layers", "batch", None, None)},
                "sk": kv, "sv": kv}
    if at == "ssm":
        return {"wkv": ("layers", "batch", "model", None, None),
                "tm": ("layers", "batch", None),
                "cm": ("layers", "batch", None)}
    raise ValueError(at)


def cache_pspecs(cfg: ModelConfig, cache_abstract) -> Any:
    names = _cache_spec_names(cfg)

    def f(path, leaf):
        node = names
        for k in path:
            node = node[k.key]
        return SH.resolve_spec(leaf.shape, node)

    return jax.tree_util.tree_map_with_path(f, cache_abstract)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------
def batch_abstract(cfg: ModelConfig, B: int, S: int, train: bool = True):
    i32 = jnp.int32
    out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if train:
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.arch_type == "vlm":
        out["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, MD.VISION_EMBED_DIM), jnp.bfloat16)
    if cfg.arch_type == "audio":
        out["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out


def batch_pspecs(cfg: ModelConfig, batch_abs):
    def spec(s):
        names = ("batch",) + (None,) * (len(s.shape) - 1)
        return SH.resolve_spec(s.shape, names)
    return jax.tree_util.tree_map(spec, batch_abs)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt,
                    compress_grads: bool = False) -> Callable:
    """compress_grads: natural-compress gradients before the optimizer —
    the on-device view of putting survey ref 75's compressor on the wire
    (unbiased, so convergence holds; examples/train_lm.py --compress)."""
    def train_step(params, opt_state, batch, *args):
        loss, grads = jax.value_and_grad(MD.lm_loss)(params, cfg, batch)
        if compress_grads:
            if args:
                key = args[0]
            else:
                # no key supplied: fold the optimizer's step counter into a
                # fixed seed so each step draws FRESH compression randomness
                # (a constant key re-uses the same rounding pattern every
                # step, which breaks the unbiasedness argument across steps)
                key = jax.random.fold_in(jax.random.PRNGKey(0),
                                         opt_state["step"])
            leaves, treedef = jax.tree_util.tree_flatten(grads)
            keys = jax.random.split(key, len(leaves))
            grads = jax.tree_util.tree_unflatten(
                treedef, [natural_compress(l, k)
                          for l, k in zip(leaves, keys)])
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss, "gnorm": gnorm}
    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        logits, _, cache = MD.forward(
            params, cfg, batch["tokens"],
            extra_embeds=batch.get("extra_embeds"),
            return_cache=True, cache_len=cache_len)
        return logits[:, -1:], cache
    return prefill_step


def sharded_argmax(logits: jax.Array) -> jax.Array:
    """argmax over the (model-sharded) vocab dim without gathering it.

    jnp.argmax over a sharded axis makes GSPMD all-gather the full logits
    (78 GB/step for a 128-batch 152k-vocab decode — the collective term
    dominated every decode pair, EXPERIMENTS.md §Perf).  Two elementwise
    passes + two scalar-per-row reduces keep the vocab dim sharded:
    cross-shard traffic drops from O(B·V) to O(B)."""
    m = jnp.max(logits, axis=-1, keepdims=True)          # (B,1) reduce
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    cand = jnp.where(logits >= m, iota, V)
    return jnp.min(cand, axis=-1).astype(jnp.int32)      # first max index


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = MD.decode_step(params, cfg, tokens, pos, cache)
        nxt = sharded_argmax(logits[:, -1])[:, None]
        return nxt, new_cache
    return serve_step


def make_serve_cb_step(cfg: ModelConfig) -> Callable:
    """Continuous-batching decode tick: one token for EVERY pool slot.

    pos: (B,) per-slot sequence lengths; active: (B,) bool slot liveness.
    Retired slots are no-ops — their cache rows are kept and their token is
    passed through unchanged, so the engine can keep ticking at full batch
    while a slot waits for backfill."""
    def serve_cb_step(params, cache, tokens, pos, active):
        logits, new_cache = MD.decode_step(params, cfg, tokens, pos, cache,
                                           active=active)
        nxt = sharded_argmax(logits[:, -1])[:, None]
        nxt = jnp.where(active[:, None], nxt, tokens)
        return nxt, new_cache
    return serve_cb_step


def make_paged_serve_cb_step(cfg: ModelConfig, logical_len: int) -> Callable:
    """Paged-pool variant of the continuous-batching tick: the cache's KV
    leaves are a shared page pool and each slot reads/writes through its
    block-table row.  logical_len is the dense cache_len the pool replaces
    (static: it bounds the gathered view)."""
    def serve_cb_paged_step(params, cache, tokens, pos, active,
                            block_tables):
        logits, new_cache = MD.decode_step(params, cfg, tokens, pos, cache,
                                           active=active,
                                           block_tables=block_tables,
                                           logical_len=logical_len)
        nxt = sharded_argmax(logits[:, -1])[:, None]
        nxt = jnp.where(active[:, None], nxt, tokens)
        return nxt, new_cache
    return serve_cb_paged_step


# ---------------------------------------------------------------------------
# Lowering plans (used by dryrun.py, train.py, serve.py)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepPlan:
    name: str
    fn: Callable
    args: Tuple[Any, ...]  # abstract ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _ns(mesh, tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))


def build_plan(cfg: ModelConfig, shape: InputShape, mesh,
               optimizer: str = "adamw") -> StepPlan:
    """Build the (fn, abstract args, shardings) plan for one arch x shape.

    Must be called under `SH.use_mesh(mesh)` and the desired `SH.axis_env`.
    """
    params_abs = MD.model_abstract(cfg)
    pspecs = MD.model_pspecs(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = get_optimizer(optimizer, warmup_cosine(3e-4, 100, 10_000))
        opt_state_abs = jax.eval_shape(opt.init, params_abs)
        opt_specs = opt.state_specs(pspecs)
        batch_abs = batch_abstract(cfg, B, S, train=True)
        bspecs = batch_pspecs(cfg, batch_abs)
        scalar = P()
        out_shardings = (_ns(mesh, pspecs), _ns(mesh, opt_specs),
                         {"loss": NamedSharding(mesh, scalar),
                          "gnorm": NamedSharding(mesh, scalar)})
        return StepPlan(
            name=f"train[{cfg.name}x{shape.name}]",
            fn=make_train_step(cfg, opt),
            args=(params_abs, opt_state_abs, batch_abs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs),
                          _ns(mesh, bspecs)),
            out_shardings=out_shardings,
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch_abs = batch_abstract(cfg, B, S, train=False)
        bspecs = batch_pspecs(cfg, batch_abs)
        # the VLM prepends patch embeddings: the cache must hold them too
        S_cache = S + (cfg.num_patches if cfg.arch_type == "vlm" else 0)
        cache_abs = MD.cache_specs(cfg, B, S_cache)
        cspecs = cache_pspecs(cfg, cache_abs)
        logit_spec = SH.resolve_spec((B, 1, cfg.vocab_size),
                                     ("batch", None, "model"))
        return StepPlan(
            name=f"prefill[{cfg.name}x{shape.name}]",
            fn=make_prefill_step(cfg, S_cache),
            args=(params_abs, batch_abs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
            out_shardings=(NamedSharding(mesh, logit_spec),
                           _ns(mesh, cspecs)),
        )

    if shape.kind == "decode":
        cache_abs = MD.cache_specs(cfg, B, S)
        cspecs = cache_pspecs(cfg, cache_abs)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        tok_spec = SH.resolve_spec((B, 1), ("batch", None))
        return StepPlan(
            name=f"decode[{cfg.name}x{shape.name}]",
            fn=make_serve_step(cfg),
            args=(params_abs, cache_abs, tok_abs, pos_abs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, tok_spec), _ns(mesh, cspecs)),
            donate_argnums=(1,),
        )

    if shape.kind == "decode_cb":
        # continuous-batching decode: per-slot position vector + active mask,
        # both sharded like the batch dim (a slot lives on one data shard)
        cache_abs = MD.cache_specs(cfg, B, S)
        cspecs = cache_pspecs(cfg, cache_abs)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        act_abs = jax.ShapeDtypeStruct((B,), jnp.bool_)
        tok_spec = SH.resolve_spec((B, 1), ("batch", None))
        row_spec = SH.resolve_spec((B,), ("batch",))
        return StepPlan(
            name=f"decode_cb[{cfg.name}x{shape.name}]",
            fn=make_serve_cb_step(cfg),
            args=(params_abs, cache_abs, tok_abs, pos_abs, act_abs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, row_spec),
                          NamedSharding(mesh, row_spec)),
            out_shardings=(NamedSharding(mesh, tok_spec), _ns(mesh, cspecs)),
            donate_argnums=(1,),
        )

    raise ValueError(shape.kind)


def lower_plan(plan: StepPlan):
    jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate_argnums)
    return jitted.lower(*plan.args)
