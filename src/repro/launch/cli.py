"""Shared launcher plumbing for the ``repro.launch`` entry points.

Every launcher (train, serve, rl) grows the same cluster surface — which
transport backs the control plane (``--transport``), where injected
failures come from (``--failure-trace``), where dying workers flush
their flight rings (``--flight-dir``) — plus the same "record the run
and write a Perfetto trace" wrapper (``--trace-out``).  They live here
once, as argparse argument groups and small factories, so a flag's
spelling, default, and semantics cannot drift between entry points:

* `add_cluster_args(ap, ...)`  — the cluster flag group
* `add_trace_args(ap)`         — the observability flag group
* `load_failure_trace(args)`   — ``--failure-trace`` JSON -> FailureTrace
* `make_transport(args, trace)`— flags -> SimTransport / ProcTransport
* `run_traced(args, fn)`       — run under a Recorder, write trace.json

All repro imports are lazy: parsing ``--help`` must not pay the jax
startup tax.
"""
from __future__ import annotations

import argparse
from typing import Any, Callable, Optional


def add_cluster_args(ap: argparse.ArgumentParser, *,
                     context: str = "the fleet",
                     workers: Optional[int] = None,
                     workers_help: Optional[str] = None):
    """Add the shared cluster control-plane flags.

    ``context`` names the launcher's fleet in help text (e.g.
    ``"--elastic"``, ``"--replicas"``).  ``--workers`` is added only
    when a default is given — serve sizes its fleet with ``--replicas``
    and rl with ``--actors``/``--replay-shards`` instead.
    """
    g = ap.add_argument_group(
        "cluster", "control plane shared by every launcher "
        "(repro.cluster; see repro.launch.cli)")
    g.add_argument("--transport", default="sim", choices=["sim", "proc"],
                   help=f"{context} control plane: 'sim' replays the "
                        "failure trace on the simulated clock; 'proc' "
                        "runs real worker processes with per-host "
                        "heartbeat RPC and injects the trace against "
                        "them (repro.cluster.ProcTransport)")
    g.add_argument("--failure-trace", default=None,
                   help="JSON trace of fail/hang/recover/join/slow "
                        "events to inject "
                        "(repro.elastic.membership.FailureTrace)")
    g.add_argument("--flight-dir", default=None,
                   help="--transport=proc: directory where dying/"
                        "stopped workers flush their flight-recorder "
                        "ring (flight_host<id>.json)")
    if workers is not None:
        g.add_argument("--workers", type=int, default=workers,
                       help=workers_help
                       or f"logical workers in {context}")
    return g


def add_trace_args(ap: argparse.ArgumentParser):
    """Add the shared observability flags."""
    g = ap.add_argument_group("observability (repro.obs)")
    g.add_argument("--trace-out", default=None,
                   help="record the run and write a Chrome/Perfetto "
                        "trace.json here (open in ui.perfetto.dev); "
                        "see repro.obs")
    return g


def load_failure_trace(args, default=None):
    """``--failure-trace`` JSON -> FailureTrace (``default`` if the flag
    was absent or the launcher never added the group)."""
    path = getattr(args, "failure_trace", None)
    if not path:
        return default
    from repro.elastic.membership import FailureTrace
    return FailureTrace.load(path)


def make_transport(args, trace=None):
    """Transport from the shared cluster flags: sim replays ``trace`` on
    the simulated clock, proc injects it against real worker processes
    (flight rings land in ``--flight-dir``)."""
    if getattr(args, "transport", "sim") == "proc":
        from repro.cluster.proc import ProcTransport
        return ProcTransport(inject=trace,
                             flight_dir=getattr(args, "flight_dir", None))
    from repro.cluster.sim import SimTransport
    from repro.elastic.membership import FailureTrace
    return SimTransport(trace or FailureTrace())


def run_traced(args, fn: Callable[[], Any]) -> Any:
    """Run ``fn()`` and, when ``--trace-out`` was given, record it and
    write the Chrome/Perfetto trace on the way out (even on error —
    a trace of a failed run is the one you want most)."""
    if not getattr(args, "trace_out", None):
        return fn()
    from repro.obs import recorder as obs
    from repro.obs.trace import write_trace
    with obs.recording(obs.Recorder()) as rec:
        try:
            return fn()
        finally:
            write_trace(args.trace_out, rec.events)
            print(f"wrote trace: {args.trace_out} "
                  f"({len(rec.events)} events)", flush=True)
