import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, record memory/cost/collective analysis for the roofline.

The two lines above MUST run before any jax import: jax locks the device
count at first init.  Do not set this flag globally — smoke tests and
benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single --out benchmarks/results
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, shape_plan
from repro.core import sharding as SH
from repro.core.roofline import analyze, model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_plan, lower_plan


def env_for(kind: str, sp: bool = False) -> SH.AxisEnv:
    # training uses ZeRO/FSDP param+optimizer sharding; serving shards params
    # on the model axis only (weights must be resident per decode step).
    # sp=True adds Megatron-SP sequence sharding of the residual stream
    # (the beyond-paper optimized variant; EXPERIMENTS.md §Perf).
    if kind == "train":
        return SH.TRAIN_SP_ENV if sp else SH.TRAIN_ENV
    return SH.DP_TP_SP_ENV if sp else SH.DP_TP_ENV


def _compile(cfg, shape, mesh, optimizer):
    plan = build_plan(cfg, shape, mesh, optimizer=optimizer)
    return lower_plan(plan).compile()


def _cost_point(cfg, shape, mesh, mesh_name, optimizer, layers):
    """Compile a reduced-depth fully-unrolled variant and return its roofline
    measurements (XLA's HloCostAnalysis counts a while-loop body once, so the
    full-depth scan compile cannot be used for FLOPs/collectives)."""
    c = _compile(cfg.with_(num_layers=layers, unroll_layers=True),
                 shape, mesh, optimizer)
    return analyze(c, cfg.name, shape.name, mesh_name, chips=mesh.size,
                   mflops=0.0)


def run_one(arch: str, shape_name: str, mesh, mesh_name: str,
            optimizer: str = "adamw", sp: bool = False, q_chunk: int = 0,
            moe_groups: int = 0):
    shape = SHAPES[shape_name]
    cfg = shape_plan(arch, shape_name)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention long-context (see DESIGN.md)"}
    cfg = cfg.with_(param_dtype="bfloat16", compute_dtype="bfloat16")
    if q_chunk:
        cfg = cfg.with_(attn_q_chunk=q_chunk)
    if moe_groups:
        cfg = cfg.with_(moe_groups=moe_groups)
    t0 = time.time()
    with SH.use_mesh(mesh), SH.axis_env(env_for(shape.kind, sp)):
        # 1) full-depth compile (scan over layers): proves the production
        #    config lowers, partitions, and fits (memory_analysis).
        compiled = _compile(cfg, shape, mesh, optimizer)
        mem = compiled.memory_analysis()

        # 2) cost model: two reduced-depth unrolled compiles -> per-layer
        #    delta -> extrapolate to full depth (exact for homogeneous
        #    stacks; ~5% high for zamba2's shared-block cadence 38 vs 36).
        la = cfg.hybrid_attn_every if cfg.arch_type == "hybrid" else 2
        lb = 2 * la
        ra = _cost_point(cfg, shape, mesh, mesh_name, optimizer, la)
        rb = _cost_point(cfg, shape, mesh, mesh_name, optimizer, lb)
        L = cfg.num_layers

        def extrap(a, b):
            return a + (b - a) / (lb - la) * (L - la)

        flops = extrap(ra.flops_per_chip, rb.flops_per_chip)
        byts = extrap(ra.bytes_per_chip, rb.bytes_per_chip)
        coll = extrap(ra.coll_bytes_per_chip, rb.coll_bytes_per_chip)
        by_op = {k: int(extrap(ra.coll_by_op[k], rb.coll_by_op[k]))
                 for k in ra.coll_by_op}

        from repro.core.roofline import Roofline
        rf = Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                      chips=mesh.size, flops_per_chip=flops,
                      bytes_per_chip=byts, coll_bytes_per_chip=coll,
                      coll_by_op=by_op,
                      model_flops_total=model_flops(
                          cfg, shape.seq_len, shape.global_batch, shape.kind))
    dt = time.time() - t0
    rec = rf.to_dict()
    rec.update(status="ok", compile_s=round(dt, 1),
               argument_bytes=int(mem.argument_size_in_bytes),
               output_bytes=int(mem.output_size_in_bytes),
               temp_bytes=int(mem.temp_size_in_bytes),
               cost_method=f"extrapolated L={la},{lb}->{L}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="Megatron-SP sequence sharding (optimized variant)")
    ap.add_argument("--q-chunk", type=int, default=0,
                    help="flash-style q-chunked attention block size")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="MoE routing groups (1 = survey-era global baseline)")
    ap.add_argument("--tag", default="",
                    help="suffix for the results file (e.g. '_opt')")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    for multi in meshes:
        mesh_name = "2x16x16" if multi else "16x16"
        mesh = make_production_mesh(multi_pod=multi)
        path = outdir / f"dryrun_{mesh_name}{args.tag}.json"
        results = json.loads(path.read_text()) if path.exists() else {}
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}|{shape_name}"
                if args.skip_existing and key in results and \
                        results[key].get("status") in ("ok", "skipped"):
                    continue
                try:
                    rec = run_one(arch, shape_name, mesh, mesh_name,
                                  args.optimizer, sp=args.sp,
                                  q_chunk=args.q_chunk,
                                  moe_groups=args.moe_groups)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                path.write_text(json.dumps(results, indent=1))
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f"bottleneck={rec['bottleneck']} "
                             f"tc={rec['t_compute']:.2e} tm={rec['t_memory']:.2e} "
                             f"tx={rec['t_collective']:.2e} "
                             f"useful={rec['useful_ratio']:.2f} "
                             f"compile={rec['compile_s']}s")
                elif status == "FAIL":
                    extra = rec["error"][:160]
                print(f"[{mesh_name}] {arch} x {shape_name}: {status} {extra}",
                      flush=True)
    print("dry-run complete")


if __name__ == "__main__":
    main()
