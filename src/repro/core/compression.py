"""Natural compression (Horvath et al., surveyed as ref 75): unbiased
stochastic rounding of gradients to powers of two.

C_nat(x) rounds |x| to one of the two nearest powers of two, with
probability proportional to the distance — E[C_nat(x)] = x (unbiased), and
the result needs only sign + 8-bit exponent = 9 bits (we pack to int8
exponent + sign bit, a 4x reduction vs fp32 wire format; the paper's
"natural" trick is that no mantissa arithmetic is needed).

Used as a gradient-aggregation hook in the data-parallel trainer
(`repro.core.data_parallel`), compressing worker->aggregator traffic
(and optionally the broadcast back = bidirectional compression).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# int8 wire format: value = sign * 2^(code - _BIAS); code 0 => zero.
_BIAS = 70


def natural_compress(x: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased stochastic rounding to the nearest powers of two."""
    a = jnp.abs(x).astype(jnp.float32)
    zero = a == 0
    e = jnp.floor(jnp.log2(jnp.where(zero, 1.0, a)))
    lo = jnp.exp2(e)
    p = (a - lo) / lo  # in [0, 1): prob of rounding UP to 2^(e+1)
    up = jax.random.uniform(key, x.shape) < p
    mag = jnp.where(up, lo * 2.0, lo)
    out = jnp.sign(x).astype(jnp.float32) * jnp.where(zero, 0.0, mag)
    return out.astype(x.dtype)


def nc_pack(x: jax.Array, key: jax.Array) -> jax.Array:
    """Compress to the int8 wire format (sign in bit 7, exponent code)."""
    a = jnp.abs(x).astype(jnp.float32)
    zero = a == 0
    e = jnp.floor(jnp.log2(jnp.where(zero, 1.0, a)))
    lo = jnp.exp2(e)
    p = (a - lo) / lo
    up = (jax.random.uniform(key, x.shape) < p).astype(jnp.int32)
    code = jnp.clip(e.astype(jnp.int32) + up + _BIAS, 1, 127)
    code = jnp.where(zero, 0, code)
    sign = (x < 0).astype(jnp.int32) << 7
    return (code | sign).astype(jnp.uint8)


def nc_unpack(b: jax.Array, dtype=jnp.float32) -> jax.Array:
    bi = b.astype(jnp.int32)
    sign = jnp.where((bi & 0x80) != 0, -1.0, 1.0)
    code = bi & 0x7F
    mag = jnp.where(code == 0, 0.0, jnp.exp2((code - _BIAS).astype(jnp.float32)))
    return (sign * mag).astype(dtype)


def compress_tree(grads, key) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [natural_compress(l, k) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def wire_bytes(tree, compressed: bool) -> int:
    """Bytes on the wire for one gradient exchange."""
    n = sum(l.size for l in jax.tree_util.tree_leaves(tree))
    return n * (1 if compressed else 4)
