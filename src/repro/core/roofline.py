"""Roofline analysis from AOT-compiled artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds-per-step:

    T_compute    = HLO_FLOPs / peak_FLOP/s          (per chip; partitioned HLO)
    T_memory     = HLO_bytes / HBM_bw               (per chip)
    T_collective = collective_bytes / ICI link bw   (per chip)

`cost_analysis()` reports the partitioned (per-device) module, so no further
division by chip count is needed.  Collective bytes are parsed from the
optimized HLO text: the summed result sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig, param_count

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] literal in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9_\[\]{},\s/*=-]*?\)?)\s*"
    r"\b(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Per-device collective bytes from (post-SPMD) optimized HLO text.

    Counts the result-shape bytes of every collective op (simple AND
    tuple-result forms — an earlier greedy-regex version silently dropped
    the simple form; tests/test_roofline.py pins both).  `-done` halves of
    async pairs are skipped (counted at `-start`)."""
    by_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue
        by_op[m.group("op")] += _shape_bytes(m.group("type"))
    return sum(by_op.values()), by_op


def model_flops(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str) -> float:
    """Analytic 'useful' FLOPs: 6·N·D train, 2·N·D inference (N = active
    non-embedding params + lm head contribution)."""
    total, active = param_count(cfg)
    emb = cfg.vocab_size * cfg.d_model * 2
    n_active = active - emb + cfg.vocab_size * cfg.d_model  # head matmul counts
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


# per-chip link-bytes per RESULT byte on a ring/torus: an all-reduce
# moves ~2x its result (reduce-scatter + all-gather phases); AG/RS/A2A/CP
# move ~1x.  (W-1)/W ~ 1 at W=16.
COLL_WEIGHTS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def weighted_coll_bytes(by_op: Dict[str, int]) -> float:
    return sum(COLL_WEIGHTS.get(op, 1.0) * b for op, b in by_op.items())


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_op: Dict[str, int]
    model_flops_total: float
    peak_memory_bytes: Optional[int] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        if self.coll_by_op and sum(self.coll_by_op.values()) > 0:
            return weighted_coll_bytes(self.coll_by_op) / ICI_BW
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        hlo_total = self.flops_per_chip * self.chips
        if hlo_total <= 0:
            return float("nan")
        return self.model_flops_total / hlo_total

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_ratio=self.useful_ratio,
                 step_lower_bound=self.step_time_lower_bound)
        return d


def analyze(compiled, arch: str, shape: str, mesh_name: str, chips: int,
            mflops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    cbytes, by_op = collective_bytes(text)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes +
                   ma.temp_size_in_bytes)
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops_per_chip=flops, bytes_per_chip=byts,
                    coll_bytes_per_chip=cbytes, coll_by_op=by_op,
                    model_flops_total=mflops, peak_memory_bytes=peak)
