"""Decoupled model-parallel training with delayed gradients
(survey §Model parallelism, refs 79 Zhuang et al. / 80 Huo et al. DDG).

A network is split into K sequential modules placed on K workers.
Synchronous backprop serializes them (backward locking); DDG breaks the
lock: at every tick each module

  * consumes the activation its predecessor produced LAST tick, and
  * updates with the output-gradient its successor produced LAST tick,

so all K modules compute concurrently and a gradient reaches module k
with staleness (K-1-k).  This file is the JAX single-controller
formulation: the per-module fwd/vjp calls inside one tick have no data
dependencies on each other (they read only last tick's buffers), which
is exactly the property that lets a real deployment run them in
parallel — tests validate convergence and the zero-staleness limit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass
class DDGState:
    params: List[Pytree]          # per-module parameters
    act_in: List[Optional[Pytree]]   # module k's input from last tick
    grad_out: List[Optional[Pytree]]  # dL/d(out_k) from last tick
    tick: int = 0


def ddg_init(params: Sequence[Pytree]) -> DDGState:
    K = len(params)
    return DDGState(list(params), [None] * K, [None] * K, 0)


def ddg_tick(state: DDGState, fns: Sequence[Callable],
             loss_fn: Callable, batch, *, lr: float = 0.05) -> Tuple[DDGState, dict]:
    """One decoupled tick.

    fns[k](params_k, x) -> y.  loss_fn(y_last, batch) -> scalar.
    batch feeds module 0 via batch["x"]; the loss reads batch (labels).

    Within the tick, every module's computation depends only on LAST
    tick's buffers — the decoupling that removes backward locking."""
    K = len(fns)
    p = state.params

    # ---- forward wave: module k consumes last tick's activation -------
    new_act = list(state.act_in)
    outs: List[Optional[Pytree]] = [None] * K
    vjps: List[Optional[Callable]] = [None] * K
    for k in range(K):
        x = batch["x"] if k == 0 else state.act_in[k]
        if x is None:
            continue  # pipeline not yet filled
        y, vjp = jax.vjp(lambda pk, xx: fns[k](pk, xx), p[k], x)
        outs[k] = y
        vjps[k] = vjp
    for k in range(K - 1):
        if outs[k] is not None:
            new_act[k + 1] = jax.lax.stop_gradient(outs[k])

    # ---- backward wave: delayed output-gradients -----------------------
    new_grad = list(state.grad_out)
    loss_val = None
    grads: List[Optional[Pytree]] = [None] * K
    for k in range(K):
        if vjps[k] is None:
            continue
        if k == K - 1:
            # the head computes a FRESH loss gradient on ITS current input
            loss_val, gout = jax.value_and_grad(
                lambda y: loss_fn(y, batch))(outs[k])
        else:
            gout = state.grad_out[k]  # successor's signal, one tick stale
            if gout is None:
                continue
        gp, gx = vjps[k](gout)
        grads[k] = gp
        if k > 0:
            new_grad[k - 1] = gx  # arrives at the predecessor NEXT tick

    # ---- apply ---------------------------------------------------------
    new_params = [
        (jax.tree_util.tree_map(lambda a, g: a - lr * g, p[k], grads[k])
         if grads[k] is not None else p[k])
        for k in range(K)
    ]
    metrics = {"loss": loss_val, "active_modules":
               sum(g is not None for g in grads)}
    return DDGState(new_params, new_act, new_grad, state.tick + 1), metrics


def sequential_step(params: Sequence[Pytree], fns: Sequence[Callable],
                    loss_fn: Callable, batch, *, lr: float = 0.05):
    """Reference: joint (locked) backprop through all modules."""
    def full(ps):
        y = batch["x"]
        for pk, fn in zip(ps, fns):
            y = fn(pk, y)
        return loss_fn(y, batch)

    loss, grads = jax.value_and_grad(full)(list(params))
    new = [jax.tree_util.tree_map(lambda a, g: a - lr * g, pk, gk)
           for pk, gk in zip(params, grads)]
    return new, loss
