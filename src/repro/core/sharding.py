"""Logical-axis sharding: map logical tensor axes to mesh axes.

The framework names logical axes ("batch", "model", "expert", "seq") and maps
them onto whatever physical mesh is active.  The mapping lives in a module
level context (set by the trainer / dry-run / tests), so model code never
hard-codes mesh axis names — the survey's data/model/hybrid parallelism
choices become different AxisEnv mappings over the same model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """Logical-axis -> mesh-axis mapping.

    batch:  axes the global batch is split over (data parallelism)
    model:  axes tensor-parallel dims (heads / ffn / experts / vocab) split over
    seq:    axes the sequence dim is split over (context parallelism; beyond-
            paper optimization, default None)
    """
    batch: Axes = None
    model: Axes = None
    seq: Axes = None
    # ZeRO/FSDP: additionally shard each param's largest replicated dim over
    # these axes (storage sharding; GSPMD all-gathers at use)
    fsdp: Axes = None

    def resolve(self, name: Optional[str]) -> Axes:
        if name is None:
            return None
        # unknown logical names (e.g. "layers", the stacked scan dim) are
        # never mesh-sharded
        return getattr(self, name, None)


# data parallel only (survey: "data parallelism")
DP_ENV = AxisEnv(batch=("pod", "data", "model"))
# hybrid data x tensor (survey: "hybrid parallelization"), the production default
DP_TP_ENV = AxisEnv(batch=("pod", "data"), model="model")
# pure tensor/model parallel (survey: "model parallelism")
TP_ENV = AxisEnv(batch=None, model=("data", "model"))
# hybrid + ZeRO param/optimizer sharding (training default for big models)
TRAIN_ENV = AxisEnv(batch=("pod", "data"), model="model", fsdp="data")
# hybrid + sequence sharding for long prefill (beyond-paper)
DP_TP_SP_ENV = AxisEnv(batch=("pod", "data"), model="model", seq="model")
# TRAIN_ENV + Megatron-SP: the residual stream (and all elementwise/norm
# work between the TP blocks) is sharded over the model axis along the
# sequence dim; GSPMD turns the TP all-reduces into reduce-scatter +
# all-gather pairs (beyond-paper; EXPERIMENTS.md §Perf)
TRAIN_SP_ENV = AxisEnv(batch=("pod", "data"), model="model", seq="model",
                       fsdp="data")

_state = threading.local()


def set_axis_env(env: AxisEnv):
    _state.env = env


def get_axis_env() -> AxisEnv:
    return getattr(_state, "env", DP_TP_ENV)


@contextlib.contextmanager
def axis_env(env: AxisEnv):
    prev = get_axis_env()
    set_axis_env(env)
    try:
        yield env
    finally:
        set_axis_env(prev)


def _mesh_shape() -> dict:
    shape = getattr(_state, "mesh_shape", None)
    if shape:
        return shape
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return dict(am.shape)
    except Exception:
        pass
    return {}


def _mesh_axis_names():
    return tuple(_mesh_shape().keys())


def set_mesh(mesh: Optional[Mesh]):
    _state.mesh_shape = dict(mesh.shape) if mesh is not None else {}


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_state, "mesh_shape", {})
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh_shape = prev


def axis_size(axes: Axes) -> int:
    shape = _mesh_shape()
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= shape.get(a, 1)
    return n


def _filter(axes: Axes, present: Tuple[str, ...]) -> Axes:
    """Drop mesh axes not present in the active mesh (e.g. 'pod' on 1 pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in present)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical(*names: Optional[str]) -> P:
    """Build a PartitionSpec from logical axis names for the active env+mesh."""
    env = get_axis_env()
    present = _mesh_axis_names()
    return P(*[_filter(env.resolve(n), present) for n in names])


def resolve_spec(shape: Tuple[int, ...], names: Tuple[Optional[str], ...]) -> P:
    """Like `logical`, but drop shardings a dim is not divisible by.

    GSPMD can pad uneven dims, but replicating a small non-divisible dim
    (e.g. whisper's 51865 vocab on 16 shards) is cheaper and predictable.
    """
    env = get_axis_env()
    present = _mesh_axis_names()
    parts = []
    for dim, name in zip(shape, names):
        axes = _filter(env.resolve(name), present)
        if axes is not None and dim % axis_size(axes) != 0:
            axes = None
        parts.append(axes)
    return P(*parts)


def resolve_param_spec(shape: Tuple[int, ...],
                       names: Tuple[Optional[str], ...]) -> P:
    """`resolve_spec` + FSDP: put env.fsdp axes on the last still-replicated
    dim that divides evenly (dim 0 of stacked layer params is excluded —
    scan unstacks it)."""
    env = get_axis_env()
    base = resolve_spec(shape, names)
    if env.fsdp is None:
        return base
    present = _mesh_axis_names()
    fs = _filter(env.fsdp, present)
    if fs is None:
        return base
    nshards = axis_size(fs)
    used = set()
    for part in base:
        if part is None:
            continue
        for a in (part if isinstance(part, tuple) else (part,)):
            used.add(a)
    fs_axes = fs if isinstance(fs, tuple) else (fs,)
    if any(a in used for a in fs_axes):
        return base
    parts = list(base)
    for i in range(len(shape) - 1, -1, -1):
        if names[i] == "layers":  # scan unstacks this dim; never shard it
            continue
        if parts[i] is None and shape[i] % nshards == 0 and shape[i] >= nshards:
            parts[i] = fs
            break
    return P(*parts)


def shard(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op outside jit/mesh).

    Non-divisible dims fall back to replicated (see `resolve_spec`).
    """
    present = _mesh_axis_names()
    if not present:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, resolve_spec(x.shape, names))
    except Exception:
        return x


def mesh_shards(name: str, mesh: Mesh) -> int:
    """Number of shards a logical axis maps to on `mesh`."""
    env = get_axis_env()
    axes = _filter(env.resolve(name), tuple(mesh.axis_names))
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    present = tuple(mesh.axis_names)

    def fix(part):
        return _filter(part, present) if part is not None else None

    return NamedSharding(mesh, P(*[fix(p) for p in spec]))
