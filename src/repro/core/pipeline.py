"""Pipeline parallelism (survey §Pipelining parallelism, GPipe-style).

TPU-native adaptation: stages are a mesh axis; activations move between
stages with `jax.lax.ppermute` inside `shard_map` (point-to-point on the ICI
torus / DCN across pods).  The schedule is synchronous microbatching
(GPipe / torchgpipe): M microbatches flow through S stages in M+S-1 ticks,
bubble fraction (S-1)/(M+S-1).  PipeDream's asynchronous weight stashing is
deliberately NOT reproduced (staleness-free training is the TPU-world norm;
see DESIGN.md §7) — its *schedule* benefit (overlap) is what ppermute gives.

Differentiable end-to-end: grad of ppermute is the reverse ppermute, so
`jax.grad` through `pipeline_apply` yields pipeline-parallel backprop with
the same bubble structure.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_apply(block_fn: Callable, stacked_params: Any, x: jax.Array,
                   mesh: Mesh, *, axis: str = "stage",
                   num_microbatches: int = 8) -> jax.Array:
    """Run `block_fn` stacks over `x` with GPipe pipelining.

    block_fn(layer_params, h) -> h, applied over a stack of L layers.
    stacked_params: pytree with leading layer dim L (L % num_stages == 0);
    layers are assigned contiguously to stages.
    x: (B, ...) with B % num_microbatches == 0.

    Returns block-stack output, numerically identical to the sequential
    application (tests/test_parallelism.py asserts this).
    """
    S = mesh.shape[axis]
    M = num_microbatches
    B = x.shape[0]
    assert B % M == 0, (B, M)
    mb = B // M

    L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert L % S == 0, f"layers {L} not divisible by stages {S}"
    per_stage = L // S
    # reshape (L, ...) -> (S, per_stage, ...); shard_map slices dim 0
    staged = jax.tree_util.tree_map(
        lambda p: p.reshape((S, per_stage) + p.shape[1:]), stacked_params)

    pspec_params = jax.tree_util.tree_map(
        lambda _: P(axis), staged)

    def stage_fn(params_s, x_all):
        # params_s: (1, per_stage, ...) local slice; x_all: full batch
        # (replicated input; only stage 0 consumes it).
        params_s = jax.tree_util.tree_map(lambda p: p[0], params_s)
        idx = jax.lax.axis_index(axis)
        xs = x_all.reshape((M, mb) + x_all.shape[1:])

        def local_stack(h):
            def body(h, lp):
                return block_fn(lp, h), None
            h, _ = jax.lax.scan(body, h, params_s)
            return h

        state = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        outputs = jnp.zeros_like(xs)
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            state, outputs = carry
            # feed microbatch t at stage 0 (zeros elsewhere / after drain)
            feed = jnp.where(t < M, 1, 0).astype(x_all.dtype)
            x_t = jax.lax.dynamic_index_in_dim(
                xs, jnp.minimum(t, M - 1), 0, keepdims=False) * feed
            inp = jnp.where(idx == 0, x_t, state)
            out = local_stack(inp)
            # last stage writes its finished microbatch t-(S-1)
            done = t - (S - 1)
            write = jnp.logical_and(idx == S - 1, done >= 0)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(done, 0), 0),
                lambda o: o, outputs)
            # rotate activations to the next stage
            state = jax.lax.ppermute(out, axis, perm)
            return state, outputs

        state, outputs = jax.lax.fori_loop(
            0, M + S - 1, tick, (state, outputs))
        # bring final outputs (resident on the last stage) to all stages
        outputs = jax.lax.psum(
            outputs * jnp.where(idx == S - 1, 1, 0).astype(outputs.dtype),
            axis)
        return outputs.reshape((B,) + x_all.shape[1:])

    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(pspec_params, P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(staged, x)


def sequential_apply(block_fn: Callable, stacked_params: Any,
                     x: jax.Array) -> jax.Array:
    """Reference: plain scan over the full stack (no pipeline)."""
    def body(h, lp):
        return block_fn(lp, h), None
    h, _ = jax.lax.scan(body, x, stacked_params)
    return h
