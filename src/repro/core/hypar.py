"""HYPAR-style hybrid-parallelism partition search (survey ref 87).

HYPAR picks, PER LAYER, whether tensors are partitioned data-parallel (D)
or model-parallel (M) so that total communication is minimized; the
optimum is a dynamic program over the layer chain with a per-layer comm
cost and a layout-transition cost between adjacent layers.

Costs (bytes, for W-way partitioning of one training step):

  D layer:   gradient all-reduce of the layer's weights  2·|w|·(W-1)/W
  M layer:   activation all-reduce (fwd) + grad all-reduce (bwd)
             2·|act|·(W-1)/W · 2
  D->M / M->D transition: reshard the boundary activation  |act|·(W-1)/W

The DP returns the per-layer assignment; `pure_cost` gives the all-D /
all-M references the survey compares against (HYPAR's claim: the hybrid
beats both on mixed stacks — validated in tests/test_hypar.py, along
with DP == brute force).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Byte counts for one layer: |weights| and |output activation| per
    global batch (both in elements; dtype width folds into `elem_bytes`)."""
    name: str
    weight_elems: int
    act_elems: int


def _frac(W: int) -> float:
    return (W - 1) / W


def layer_comm(layer: LayerCost, choice: str, W: int,
               elem_bytes: int = 4) -> float:
    if choice == "D":
        return 2.0 * layer.weight_elems * _frac(W) * elem_bytes
    if choice == "M":
        return 4.0 * layer.act_elems * _frac(W) * elem_bytes
    raise ValueError(choice)


def transition_comm(prev: str, cur: str, boundary_act: int, W: int,
                    elem_bytes: int = 4) -> float:
    return 0.0 if prev == cur else boundary_act * _frac(W) * elem_bytes


def hypar_partition(layers: Sequence[LayerCost], W: int,
                    elem_bytes: int = 4) -> Tuple[List[str], float]:
    """DP over the chain; returns (per-layer choices, total comm bytes)."""
    choices = ("D", "M")
    # best[c] = (cost, path) of prefix ending with choice c
    best = {c: (layer_comm(layers[0], c, W, elem_bytes), [c])
            for c in choices}
    for i in range(1, len(layers)):
        nxt = {}
        for c in choices:
            lc = layer_comm(layers[i], c, W, elem_bytes)
            cands = []
            for p in choices:
                t = transition_comm(p, c, layers[i - 1].act_elems, W,
                                    elem_bytes)
                cands.append((best[p][0] + t + lc, best[p][1] + [c]))
            nxt[c] = min(cands, key=lambda x: x[0])
        best = nxt
    cost, path = min(best.values(), key=lambda x: x[0])
    return path, cost


def pure_cost(layers: Sequence[LayerCost], choice: str, W: int,
              elem_bytes: int = 4) -> float:
    return sum(layer_comm(l, choice, W, elem_bytes) for l in layers)


def brute_force(layers: Sequence[LayerCost], W: int,
                elem_bytes: int = 4) -> Tuple[List[str], float]:
    """Exhaustive reference for tests (exponential — small N only)."""
    bestc, bestp = float("inf"), None
    for assign in itertools.product("DM", repeat=len(layers)):
        c = layer_comm(layers[0], assign[0], W, elem_bytes)
        for i in range(1, len(layers)):
            c += transition_comm(assign[i - 1], assign[i],
                                 layers[i - 1].act_elems, W, elem_bytes)
            c += layer_comm(layers[i], assign[i], W, elem_bytes)
        if c < bestc:
            bestc, bestp = c, list(assign)
    return bestp, bestc


def transformer_layer_costs(d_model: int, d_ff: int, seq: int,
                            batch: int, num_layers: int) -> List[LayerCost]:
    """Chain of attention/MLP layers for a decoder stack (per-layer
    weight and activation element counts)."""
    out = []
    act = batch * seq * d_model
    for i in range(num_layers):
        out.append(LayerCost(f"attn{i}", 4 * d_model * d_model, act))
        out.append(LayerCost(f"mlp{i}", 3 * d_model * d_ff, act))
    return out
