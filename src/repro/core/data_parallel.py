"""Data parallelism with the survey's aggregation / communication variants.

The worker dimension is explicit (leading axis W on per-worker state), so the
same code runs single-device (vmap semantics; unit tests), on a CPU host mesh
via shard_map (integration tests map W to the "data" mesh axis and the
jnp.mean over W becomes a psum — `tests/test_parallelism.py` proves they
agree), and on the production mesh via pjit (the launcher path).

Implemented survey techniques (§Distributed deep learning / data parallelism):
  * synchronous S-SGD with All-Reduce aggregation            [refs 73, 92-94]
  * parameter-server aggregation (gather-to-root + broadcast) [ref 72, 67]
  * local SGD / bounded staleness (Downpour's async adaptation) [ref 67]
  * EASGD: elastic averaging against a center variable        [ref 68]
  * DETSGRAD: event-triggered communication                   [ref 69]
  * natural compression of gradient traffic                   [ref 75]
  * DBS: dynamic batch sizing by worker throughput            [ref 71]

Each step function returns (new_state..., metrics) where metrics include
`comm_bytes` and `comm_events` so benchmarks can reproduce the papers'
communication-saving claims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import natural_compress, wire_bytes

Pytree = Any


def _tmap(f, *ts):
    return jax.tree_util.tree_map(f, *ts)


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


def per_worker_grads(loss_fn: Callable, params: Pytree, batches: Pytree):
    """batches have leading worker axis W; params are shared (replicated)."""
    def one(batch):
        return jax.value_and_grad(loss_fn)(params, batch)
    return jax.vmap(one)(batches)  # losses (W,), grads with leading W


# ---------------------------------------------------------------------------
# Aggregation modes (survey: parameter server vs All-Reduce)
# ---------------------------------------------------------------------------
def aggregate(grads_w: Pytree, mode: str = "allreduce",
              compress_key: Optional[jax.Array] = None
              ) -> Tuple[Pytree, Dict[str, Any]]:
    """grads_w: gradients with leading worker axis W.

    "allreduce": every worker ends with the mean (ring/torus collective —
      wire bytes per worker ≈ 2·P·(W-1)/W for reduce-scatter+all-gather).
    "ps": workers send to a root which averages and broadcasts (root link
      carries W·P in + W·P out — the PS bottleneck the survey describes).
    With `compress_key`, worker->aggregator traffic is natural-compressed
    (unbiased; bidirectional compression is benchmarked separately).
    """
    W = jax.tree_util.tree_leaves(grads_w)[0].shape[0]
    sent = grads_w
    if compress_key is not None:
        leaves, treedef = jax.tree_util.tree_flatten(grads_w)
        keys = jax.random.split(compress_key, len(leaves))
        leaves = [natural_compress(l, k) for l, k in zip(leaves, keys)]
        sent = jax.tree_util.tree_unflatten(treedef, leaves)
    mean = _tmap(lambda g: jnp.mean(g.astype(jnp.float32), 0), sent)

    n_elems = sum(l.size // W for l in jax.tree_util.tree_leaves(grads_w))
    elem_bytes = 1 if compress_key is not None else 4
    if mode == "allreduce":
        per_worker = 2 * n_elems * (W - 1) // W * elem_bytes
        comm = {"comm_bytes": per_worker * W, "bottleneck_link_bytes": per_worker}
    elif mode == "ps":
        comm = {"comm_bytes": 2 * W * n_elems * elem_bytes,
                "bottleneck_link_bytes": 2 * W * n_elems * elem_bytes}
    else:
        raise ValueError(mode)
    comm["comm_events"] = W
    return mean, comm


def sync_step(loss_fn, params, opt, opt_state, batches_w, *,
              mode="allreduce", compress_key=None):
    """Synchronous S-SGD: one data-parallel step (survey Fig. 2)."""
    losses, grads_w = per_worker_grads(loss_fn, params, batches_w)
    g, comm = aggregate(grads_w, mode, compress_key)
    new_params, new_state = opt.update(g, opt_state, params)
    metrics = {"loss": jnp.mean(losses), **comm}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# Local SGD (bounded-staleness adaptation of Downpour's async updates)
# ---------------------------------------------------------------------------
def local_sgd_round(loss_fn, params_w, opt, opt_states_w, batches_wk, *,
                    sync: bool = True):
    """K local steps per worker, then (optionally) average.

    params_w: worker-stacked params (W, ...); batches_wk: (W, K, ...).
    XLA's single-controller model is bulk-synchronous, so Downpour's
    asynchrony is reproduced as bounded staleness K (see DESIGN.md §7).
    """
    K = jax.tree_util.tree_leaves(batches_wk)[0].shape[1]

    def worker(params, opt_state, batches_k):
        def step(carry, batch):
            p, s = carry
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            p, s = opt.update(g, s, p)
            return (p, s), loss
        (p, s), losses = jax.lax.scan(step, (params, opt_state), batches_k)
        return p, s, losses

    params_w, opt_states_w, losses = jax.vmap(worker)(
        params_w, opt_states_w, batches_wk)
    comm_bytes = 0
    if sync:
        mean = _tmap(lambda p: jnp.mean(p.astype(jnp.float32), 0), params_w)
        W = jax.tree_util.tree_leaves(params_w)[0].shape[0]
        params_w = _tmap(
            lambda m, p: jnp.broadcast_to(m.astype(p.dtype)[None], p.shape),
            mean, params_w)
        comm_bytes = 2 * tree_bytes(mean) * (W - 1)
    return params_w, opt_states_w, {"loss": jnp.mean(losses),
                                    "comm_bytes": comm_bytes}


# ---------------------------------------------------------------------------
# EASGD (ref 68): elastic force against a center variable
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EASGDConfig:
    lr: float = 0.05
    rho: float = 0.1     # elastic coefficient (alpha = lr * rho)
    comm_every: int = 1  # tau: local steps between elastic updates


def easgd_round(loss_fn, params_w, center, batches_wk, cfg: EASGDConfig):
    """One communication round: tau local SGD steps then the elastic update.

      x_i <- x_i - lr*grad - alpha*(x_i - x~)
      x~  <- x~ + beta/W * sum_i (x_i - x~)       (beta = alpha * W)
    """
    alpha = cfg.lr * cfg.rho

    def worker(params, batches_k):
        def step(p, batch):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            p = _tmap(lambda x, gg: x - cfg.lr * gg, p, g)
            return p, loss
        return jax.lax.scan(step, params, batches_k)

    params_w, losses = jax.vmap(worker)(params_w, batches_wk)
    # elastic move toward/of the center
    diff = _tmap(lambda p, c: p - c[None], params_w, center)
    params_w = _tmap(lambda p, d: p - alpha * d, params_w, diff)
    center = _tmap(lambda c, d: c + alpha * jnp.sum(d, 0), center, diff)
    comm = 2 * tree_bytes(center) * jax.tree_util.tree_leaves(params_w)[0].shape[0]
    return params_w, center, {"loss": jnp.mean(losses), "comm_bytes": comm}


# ---------------------------------------------------------------------------
# DETSGRAD (ref 69): event-triggered parameter broadcast
# ---------------------------------------------------------------------------
def detsgrad_step(loss_fn, params_w, bcast_w, step, batches_w, *,
                  lr: float = 0.05, c0: float = 1.0, decay: float = 0.505):
    """Each worker broadcasts its params only when the drift since its last
    broadcast exceeds the (decaying) threshold; consensus uses the latest
    broadcast copies.  Returns per-step comm events (the paper's metric).

      trigger_i:  ||x_i - x^_i||_1 >= c0 / (step+1)^decay
    """
    def consensus(bc):
        return _tmap(lambda b: jnp.mean(b, 0), bc)

    mean_bc = consensus(bcast_w)

    def worker(p, bhat, batch):
        # consensus step pulls toward the mean of broadcast copies
        p = _tmap(lambda x, m: 0.5 * x + 0.5 * m, p, mean_bc)
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        p = _tmap(lambda x, gg: x - lr * gg, p, g)
        drift = sum(jnp.sum(jnp.abs(x - h))
                    for x, h in zip(jax.tree_util.tree_leaves(p),
                                    jax.tree_util.tree_leaves(bhat)))
        thresh = c0 / jnp.power(step.astype(jnp.float32) + 1.0, decay)
        fire = drift >= thresh
        new_bhat = jax.tree_util.tree_map(
            lambda x, h: jnp.where(fire, x, h), p, bhat)
        return p, new_bhat, fire, loss

    params_w, bcast_w, fires, losses = jax.vmap(worker)(
        params_w, bcast_w, batches_w)
    n_params = tree_bytes(mean_bc)
    metrics = {"loss": jnp.mean(losses),
               "comm_events": jnp.sum(fires),
               "comm_bytes": jnp.sum(fires) * n_params}
    return params_w, bcast_w, metrics


# ---------------------------------------------------------------------------
# DBS (ref 71): dynamic batch sizing from per-worker throughput
# ---------------------------------------------------------------------------
def dbs_partition(samples_per_sec: jax.Array, global_batch: int,
                  multiple: int = 1) -> jax.Array:
    """Split `global_batch` across workers proportional to throughput.

    Returns integer batch sizes summing exactly to global_batch (largest-
    remainder rounding to `multiple`)."""
    units = global_batch // multiple
    rate = samples_per_sec / jnp.sum(samples_per_sec)
    raw = rate * units
    base = jnp.floor(raw).astype(jnp.int32)
    rem = units - jnp.sum(base)
    frac = raw - base
    rank = jnp.argsort(jnp.argsort(-frac))  # 0 = largest remainder
    bump = (rank < rem).astype(jnp.int32)
    return (base + bump) * multiple


def dbs_epoch_time(samples_per_sec: jax.Array, split: jax.Array) -> jax.Array:
    """Synchronous epoch time = slowest worker (the survey's straggler cost)."""
    return jnp.max(split / samples_per_sec)
