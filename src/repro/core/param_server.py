"""Parameter-server update math and bounded-staleness clocks.

The survey's taxonomy splits distributed training along two axes:
*centralized* (parameter server) vs. *decentralized* (all-reduce)
topology, and *asynchronous* vs. *(stale-)synchronous* consistency.
`core.data_parallel` implements the all-reduce family; this module is
its centralized counterpart — the server-side state a `ParamServer`
host owns, shared verbatim by the in-process `SimTransport` shards and
the real `ProcTransport` PS child processes.

Deliberately numpy-only (no jax): the proc-transport PS child must be
able to import this without paying the jax startup tax, and server-side
SGD in float32 numpy is bit-identical whether the shard lives in the
driver process (sim) or behind a pipe (proc).

Three pieces:

* `PSShard` — a versioned key->array store with Downpour-style server
  SGD (optionally with server-side momentum): workers *push* gradients,
  the shard folds them in and bumps its version; workers *pull* the
  current parameters.  Per-worker push clocks ride along so SSP
  consistency can be audited server-side.
* `SSPClockGate` — the stale-synchronous-parallel admission rule: a
  worker may advance to clock c+1 only while `c+1 - min_clock <= s`.
  With `staleness=None` the gate never blocks (fully async).  The
  coordinator wires death transitions to `drop`, so a dead straggler
  releases the fleet instead of freezing it.
* `encode_entries` / `decode_entries` — exact float32 wire codec
  (base64 of raw bytes) for the proc transport's line-JSON pipes; exact
  round-trip is what makes sim/proc training bit-identical.
"""
from __future__ import annotations

import base64
from typing import Dict, List, Optional, Tuple

import numpy as np

Entries = Dict[str, np.ndarray]


class PSShard:
    """One versioned key-value shard of the parameter server.

    ``push`` applies plain SGD (`w -= lr * g`, float32, optional heavy
    momentum buffer) immediately — there is no barrier and no gradient
    bucket; interleaving IS the async-PS semantics.  ``version`` counts
    applied pushes so clients can observe how stale a pull was.
    """

    def __init__(self, lr: float, momentum: float = 0.0):
        self.lr = float(lr)
        self.momentum = float(momentum)
        self.store: Entries = {}
        self._vel: Entries = {}
        self.version = 0
        self.clocks: Dict[int, int] = {}  # worker -> last pushed clock

    def init(self, entries: Entries) -> None:
        for k, v in entries.items():
            self.store[k] = np.array(v, np.float32)

    def push(self, worker: int, clock: int, grads: Entries) -> int:
        for k, g in grads.items():
            g = np.asarray(g, np.float32)
            if self.momentum:
                vel = self._vel.get(k)
                vel = g if vel is None else (self.momentum * vel + g
                                             ).astype(np.float32)
                self._vel[k] = vel
                g = vel
            self.store[k] = (self.store[k] - self.lr * g).astype(np.float32)
        self.version += 1
        self.clocks[int(worker)] = int(clock)
        return self.version

    def pull(self) -> Tuple[int, Entries]:
        return self.version, {k: v.copy() for k, v in self.store.items()}

    def forget(self, worker: int) -> None:
        self.clocks.pop(int(worker), None)


class SSPClockGate:
    """Bounded-staleness admission over per-worker clocks.

    A worker at clock c may start the step taking it to c+1 only if
    ``c + 1 - min_clock <= staleness`` — so the observed clock gap
    never exceeds `s`, and a worker blocked at exactly gap `s` is
    released the moment the slowest registered worker advances (or
    dies and is dropped).
    """

    def __init__(self, staleness: Optional[int] = None):
        if staleness is not None and staleness < 0:
            raise ValueError("staleness must be >= 0 (or None for async)")
        self.staleness = staleness
        self.clocks: Dict[int, int] = {}

    def register(self, worker: int, clock: int = 0) -> None:
        self.clocks[int(worker)] = int(clock)

    def drop(self, worker: int) -> None:
        self.clocks.pop(int(worker), None)

    def min_clock(self) -> int:
        return min(self.clocks.values()) if self.clocks else 0

    def gap(self, worker: int) -> int:
        return self.clocks[worker] - self.min_clock()

    def can_advance(self, worker: int) -> bool:
        if self.staleness is None or len(self.clocks) <= 1:
            return True
        return self.clocks[worker] + 1 - self.min_clock() <= self.staleness

    def advance(self, worker: int) -> int:
        self.clocks[worker] += 1
        return self.clocks[worker]


def shard_keys(keys: List[str], num_shards: int) -> List[List[str]]:
    """Deterministic round-robin partition of sorted keys over shards."""
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    out: List[List[str]] = [[] for _ in range(num_shards)]
    for i, k in enumerate(sorted(keys)):
        out[i % num_shards].append(k)
    return out


# ---------------------------------------------------------------------------
# float32 wire codec for the proc transport's line-JSON pipes
# ---------------------------------------------------------------------------
def encode_entries(entries: Entries) -> Dict[str, Dict]:
    wire = {}
    for k, v in entries.items():
        arr = np.ascontiguousarray(np.asarray(v, np.float32))
        wire[k] = {"shape": list(arr.shape),
                   "b64": base64.b64encode(arr.tobytes()).decode("ascii")}
    return wire


def decode_entries(wire: Dict[str, Dict]) -> Entries:
    out = {}
    for k, spec in wire.items():
        buf = base64.b64decode(spec["b64"])
        out[k] = np.frombuffer(buf, np.float32).reshape(spec["shape"]).copy()
    return out
