"""Sharded prioritized trajectory replay + published-params store.

The server-side state of the actor–learner fleet's two new cluster
roles (`cluster.roles`: "replay" and "learner"), mirroring
`core.param_server`'s conventions exactly:

* deliberately numpy-only (no jax) — the proc transport's replay/learner
  children must import this without the jax startup tax, and float32
  numpy server math is bit-identical whether the shard lives in the
  driver process (sim) or behind a pipe (proc);
* versioned stores, so clients can observe how stale a pull/sample was;
* all wire traffic rides the exact `param_server.encode_entries` codec.

Three pieces:

* `ReplayShard` — one shard of the Ape-X-style prioritized replay
  service (survey ref 104): actors *push* whole trajectories (leaves
  keyed by name, leading item axis) with initial priorities; learners
  *sample* proportional to priority^alpha with importance weights
  (beta-annealing left to the client) and *update* priorities from
  fresh TD errors.  Sampling is seeded BY THE REQUESTER, so a replayed
  command stream reproduces the identical sample — determinism lives in
  the protocol, not the process.
* `ParamStore` — the learner role's versioned published-parameters
  store: the learner computes its own updates (unlike `PSShard`, there
  is no server-side SGD) and *publishes*, bumping the version actors
  watch; actors *pull* the current snapshot.
* `stratified_assign` — the priority-stratified sharding key: rank
  items by priority and deal them round-robin across shards, so every
  shard holds a cross-section of the priority spectrum and a killed
  shard costs coverage, not a priority band (the fleet degrades
  unbiased to the survivors).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

Entries = Dict[str, np.ndarray]

_EPS = 1e-6  # priority floor: a written slot is never unsampleable


class ReplayShard:
    """One versioned shard of the prioritized trajectory replay.

    Storage is a fixed-capacity ring per leaf, allocated lazily on the
    first push (the shard learns the trajectory schema from the data).
    Unwritten slots keep priority 0.0 and can never be sampled — the
    proportional draw's support is exactly the written region.
    """

    def __init__(self, capacity: int, alpha: float = 0.6,
                 beta: float = 0.4, seed: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.alpha = float(alpha)
        self.beta = float(beta)
        self.seed = int(seed)
        self.store: Entries = {}
        self.prios = np.zeros(self.capacity, np.float64)
        self.cursor = 0
        self.size = 0
        self.version = 0
        self.pushes = 0          # items ever written
        self.sampled = 0         # items ever served

    def push(self, actor: int, clock: int, items: Entries,
             priorities: np.ndarray) -> int:
        """Ring-write `n` items (leaves shaped (n, ...)) with their
        initial priorities; returns the bumped shard version.  `actor`/
        `clock` ride along for parity with `PSShard.push` telemetry."""
        del actor, clock
        priorities = np.asarray(priorities, np.float64).reshape(-1)
        n = priorities.shape[0]
        if n == 0:
            return self.version
        if n > self.capacity:
            raise ValueError(f"push of {n} items exceeds shard capacity "
                             f"{self.capacity}")
        idx = (self.cursor + np.arange(n)) % self.capacity
        for key, arr in items.items():
            arr = np.asarray(arr, np.float32)
            if arr.shape[0] != n:
                raise ValueError(f"leaf {key!r} has {arr.shape[0]} items, "
                                 f"priorities have {n}")
            if key not in self.store:
                self.store[key] = np.zeros((self.capacity,) + arr.shape[1:],
                                           np.float32)
            self.store[key][idx] = arr
        self.prios[idx] = (np.abs(priorities) + _EPS) ** self.alpha
        self.cursor = int((self.cursor + n) % self.capacity)
        self.size = min(self.size + n, self.capacity)
        self.version += 1
        self.pushes += n
        return self.version

    def sample(self, batch: int, seed: int
               ) -> Tuple[np.ndarray, Entries, np.ndarray]:
        """Draw `batch` items (with replacement) proportional to
        priority; returns (slot indices, items, float32 importance
        weights normalized by their max).  `seed` comes from the
        requester so replaying the command stream replays the draw."""
        if self.size == 0:
            raise ValueError("sample from an empty shard")
        p = self.prios / self.prios.sum()
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, int(seed))))
        idx = rng.choice(self.capacity, size=int(batch), replace=True, p=p)
        w = (self.size * p[idx]) ** -self.beta
        w = (w / w.max()).astype(np.float32)
        items = {k: v[idx] for k, v in self.store.items()}
        self.sampled += int(batch)
        return idx, items, w

    def update(self, idx: np.ndarray, priorities: np.ndarray) -> None:
        """Re-prioritize previously sampled slots from fresh TD errors
        (the learner's half of the Ape-X loop)."""
        idx = np.asarray(idx, np.int64)
        priorities = np.asarray(priorities, np.float64).reshape(-1)
        self.prios[idx] = (np.abs(priorities) + _EPS) ** self.alpha
        self.version += 1

    def stats(self) -> Dict[str, float]:
        return {"size": self.size, "capacity": self.capacity,
                "version": self.version, "pushes": self.pushes,
                "sampled": self.sampled}


class ParamStore:
    """Versioned published-parameters store — the learner role's state.

    Mirrors `PSShard`'s versioned-KV surface minus the server-side SGD:
    the learner owns its optimizer and publishes finished parameters;
    `version` counts publishes, which is the staleness unit actors
    report (pulled version vs. the learner's latest)."""

    def __init__(self):
        self.store: Entries = {}
        self.version = 0

    def publish(self, entries: Entries) -> int:
        for k, v in entries.items():
            self.store[k] = np.array(v, np.float32)
        self.version += 1
        return self.version

    def pull(self) -> Tuple[int, Entries]:
        return self.version, {k: v.copy() for k, v in self.store.items()}


def stratified_assign(priorities: np.ndarray, num_shards: int) -> np.ndarray:
    """Shard index per item, stratified by priority rank: sort items by
    descending priority (stable) and deal round-robin, so each shard's
    holdings span the full priority spectrum.  Deterministic, and the
    reason shard death degrades coverage instead of deleting the
    high-priority band."""
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    priorities = np.asarray(priorities, np.float64).reshape(-1)
    order = np.argsort(-priorities, kind="stable")
    assign = np.empty(priorities.shape[0], np.int64)
    assign[order] = np.arange(priorities.shape[0]) % num_shards
    return assign
